"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, chunk-parallel)
and sLSTM (scalar memory, strictly sequential recurrence).

mLSTM trains in a chunkwise linear-attention form.  With F_t = Σ_{r≤t} log f_r
(within-chunk) and inbound stabilized state (C̃, ñ, m_in):

  D_tj   = exp(F_t - F_j + log i_j)          (intra-chunk pair decay, j ≤ t)
  m_t    = max(max_j log D_tj, F_t + m_in)   (stabilizer)
  num_t  = Σ_j e^{logD-m_t} (q·k_j) v_j + e^{F_t+m_in-m_t} q·C̃
  den_t  = Σ_j e^{logD-m_t} (q·k_j)     + e^{F_t+m_in-m_t} q·ñ
  y_t    = num_t / max(|den_t|, e^{-m_t})

which reduces to the O(1) decode step at chunk length 1.  The chunk scan is
unrollable for the roofline delta method.  sLSTM keeps a true sequential
scan (its gates feed back through h_{t-1}); the roofline harness accounts its
FLOPs as step-program-FLOPs × S (EXPERIMENTS.md §Roofline-method).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import XLSTMConfig
from repro.dist.context import ShardCtx
from repro.models import nn
from repro.models.nn import KeyGen

NEG = -1e30


# --------------------------------------------------------------------------
# mLSTM
# --------------------------------------------------------------------------
def init_mlstm(kg: KeyGen, d: int, num_heads: int, xc: XLSTMConfig, dtype) -> dict:
    di = int(d * xc.proj_factor_mlstm)
    bs = min(xc.qkv_blocksize, di)
    nb = di // bs
    return {
        "up": nn.dense_init(kg(), (d, 2 * di), ("embed", "mamba_inner"), dtype),
        # block-diagonal projections (paper's qkv_proj_blocksize): [nb, bs, bs]
        "wq": nn.dense_init(kg(), (nb, bs, bs), ("mamba_inner", None, None), dtype),
        "wk": nn.dense_init(kg(), (nb, bs, bs), ("mamba_inner", None, None), dtype),
        "wv": nn.dense_init(kg(), (nb, bs, bs), ("mamba_inner", None, None), dtype),
        "wi": nn.dense_init(kg(), (di, num_heads), (None, "lstm_heads"), jnp.float32, scale=0.01),
        "wf": nn.dense_init(kg(), (di, num_heads), (None, "lstm_heads"), jnp.float32, scale=0.01),
        "bi": nn.zeros_init((num_heads,), ("lstm_heads",), jnp.float32),
        "bf": nn.Param(jnp.full((num_heads,), 3.0, jnp.float32), ("lstm_heads",)),
        "ogate": nn.dense_init(kg(), (d, di), ("embed", "mamba_inner"), dtype),
        "down": nn.dense_init(kg(), (di, d), ("mamba_inner", "embed"), dtype),
    }


def init_mlstm_state(B: int, H: int, hd: int) -> dict:
    return {
        "C": jnp.zeros((B, H, hd, hd), jnp.float32),
        "n": jnp.zeros((B, H, hd), jnp.float32),
        "m": jnp.full((B, H), NEG, jnp.float32),
    }


def _mlstm_step(q, k, v, li, lf, state):
    """Single recurrent step (decode).  q/k/v: [B,H,hd]; li/lf: [B,H] (log)."""
    C, n, m = state["C"], state["n"], state["m"]
    m_new = jnp.maximum(lf + m, li)
    f = jnp.exp(lf + m - m_new)[..., None]
    i = jnp.exp(li - m_new)[..., None]
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    C_new = f[..., None] * C + (i * kf)[..., None] * vf[..., None, :]
    n_new = f * n + i * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhk,bhkv->bhv", qf, C_new)
    den = jnp.abs(jnp.einsum("bhk,bhk->bh", qf, n_new))
    y = num / jnp.maximum(den, jnp.exp(-m_new))[..., None]
    return y, {"C": C_new, "n": n_new, "m": m_new}


def _mlstm_chunked(q, k, v, li, lf, state, chunk: int, unroll: bool):
    """[B,S,H,hd] inputs -> (y [B,S,H,hd], final state)."""
    B, S, H, hd = q.shape
    Q = min(chunk, S)
    nc = -(-S // Q)
    pad = nc * Q - S
    if pad:  # identity steps: i-gate -inf (no write), f-gate 0 (no decay)
        zpad = ((0, 0), (0, pad), (0, 0), (0, 0))
        q, k, v = (jnp.pad(t, zpad) for t in (q, k, v))
        li = jnp.pad(li, ((0, 0), (0, pad), (0, 0)), constant_values=NEG)
        lf = jnp.pad(lf, ((0, 0), (0, pad), (0, 0)))

    def to_chunks(t):
        perm = (1, 0) + tuple(range(2, t.ndim + 1))
        return t.reshape(B, nc, Q, *t.shape[2:]).transpose(*perm)

    qc, kc, vc, lic, lfc = map(to_chunks, (q, k, v, li, lf))

    def chunk_body(carry, blk):
        C, n, m = carry                       # stabilized inbound state
        qb, kb, vb, lib, lfb = blk            # [B,Q,H,hd], gates [B,Q,H]
        qf = qb.astype(jnp.float32)
        kf = kb.astype(jnp.float32)
        vf = vb.astype(jnp.float32)
        F = jnp.cumsum(lfb, axis=1)           # [B,Q,H]
        g = F[:, :, None, :] - F[:, None, :, :] + lib[:, None, :, :]  # [B,t,j,H]
        causal = jnp.tril(jnp.ones((Q, Q), bool))
        g = jnp.where(causal[None, :, :, None], g, NEG)
        a_state = F + m[:, None]              # [B,Q,H]
        m_t = jnp.maximum(jnp.max(g, axis=2), a_state)
        w = jnp.exp(g - m_t[:, :, None, :])
        s = jnp.einsum("bthk,bjhk->btjh", qf, kf)
        sw = s * w
        dec = jnp.exp(a_state - m_t)          # [B,Q,H]
        num = jnp.einsum("btjh,bjhv->bthv", sw, vf) \
            + jnp.einsum("bthk,bhkv->bthv", qf, C) * dec[..., None]
        den = sw.sum(axis=2) + jnp.einsum("bthk,bhk->bth", qf, n) * dec
        y = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # outbound state (stabilized at m_out)
        gQ = g[:, -1]                          # [B,j,H] log decay to chunk end
        m_out = jnp.maximum(a_state[:, -1], jnp.max(gQ, axis=1))
        wq = jnp.exp(gQ - m_out[:, None])      # [B,j,H]
        decQ = jnp.exp(a_state[:, -1] - m_out)
        C_out = decQ[..., None, None] * C + jnp.einsum("bjh,bjhk,bjhv->bhkv", wq, kf, vf)
        n_out = decQ[..., None] * n + jnp.einsum("bjh,bjhk->bhk", wq, kf)
        return (C_out, n_out, m_out), y

    (C, n, m), y = jax.lax.scan(chunk_body, (state["C"], state["n"], state["m"]),
                                (qc, kc, vc, lic, lfc), unroll=nc if unroll else 1)
    y = y.transpose(1, 0, 2, 3, 4).reshape(B, nc * Q, H, hd)[:, :S]
    return y, {"C": C, "n": n, "m": m}


def mlstm_apply(p: dict, x, num_heads: int, xc: XLSTMConfig, ctx: ShardCtx, *,
                state: dict | None = None, unroll: bool = False):
    """x: [B, S, d] -> (y, new_state)."""
    B, S, d = x.shape
    up = jnp.einsum("bsd,de->bse", x, p["up"].value)
    xr, res = jnp.split(up, 2, axis=-1)
    di = xr.shape[-1]
    H = num_heads
    hd = di // H
    nb, bs = p["wq"].value.shape[0], p["wq"].value.shape[1]

    def blockdiag(t, w):  # [B,S,di] x [nb,bs,bs] -> [B,S,di], then head split
        y = jnp.einsum("bsnk,nkl->bsnl", t.reshape(B, S, nb, bs), w)
        return y.reshape(B, S, H, hd)

    q = blockdiag(xr, p["wq"].value) * hd ** -0.5
    k = blockdiag(xr, p["wk"].value)
    v = blockdiag(xr, p["wv"].value)
    li = jnp.einsum("bsi,ih->bsh", xr.astype(jnp.float32), p["wi"].value) + p["bi"].value
    lf = jnp.einsum("bsi,ih->bsh", xr.astype(jnp.float32), p["wf"].value) + p["bf"].value
    lf = jax.nn.log_sigmoid(lf)
    if state is None:
        state = init_mlstm_state(B, H, hd)
    if S == 1:
        y, new_state = _mlstm_step(q[:, 0], k[:, 0], v[:, 0], li[:, 0], lf[:, 0], state)
        y = y[:, None]
    else:
        y, new_state = _mlstm_chunked(q, k, v, li, lf, state, xc.chunk_size, unroll)
    y = y.reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(jnp.einsum("bsd,de->bse", x, p["ogate"].value))
    y = y + res
    out = jnp.einsum("bsi,id->bsd", y, p["down"].value)
    return ctx.constrain(out, ("batch", "seq", "embed")), new_state


# --------------------------------------------------------------------------
# sLSTM
# --------------------------------------------------------------------------
def init_slstm(kg: KeyGen, d: int, num_heads: int, xc: XLSTMConfig, dtype) -> dict:
    dh = d // num_heads
    dff = int(d * xc.proj_factor_slstm)
    return {
        "wx": nn.dense_init(kg(), (d, 4, d), ("embed", None, "mamba_inner"), dtype),
        "r": nn.dense_init(kg(), (num_heads, dh, 4, dh),
                           ("lstm_heads", None, None, None), dtype, scale=dh ** -0.5),
        "b": nn.Param(
            jnp.zeros((4, d), jnp.float32).at[1].set(3.0),  # forget-gate bias 3
            (None, "mamba_inner")),
        "up": nn.dense_init(kg(), (d, 2 * dff), ("embed", "ffn"), dtype),
        "down": nn.dense_init(kg(), (dff, d), ("ffn", "embed"), dtype),
    }


def init_slstm_state(B: int, d: int) -> dict:
    z = jnp.zeros((B, d), jnp.float32)
    return {"c": z, "n": z + 1e-6, "h": z, "m": z + NEG}


def _slstm_step(xproj, r, state, num_heads: int):
    """xproj: [B, 4, d] precomputed input projection; recurrent part here."""
    c, n, h, m = state["c"], state["n"], state["h"], state["m"]
    B, _, d = xproj.shape
    dh = d // num_heads
    hh = h.reshape(B, num_heads, dh)
    rec = jnp.einsum("bhk,hkgl->bghl", hh.astype(r.dtype), r).reshape(B, 4, d)
    gates = xproj.astype(jnp.float32) + rec.astype(jnp.float32)
    li, lf, z, o = gates[:, 0], gates[:, 1], gates[:, 2], gates[:, 3]
    lf = jax.nn.log_sigmoid(lf)
    m_new = jnp.maximum(lf + m, li)
    f = jnp.exp(lf + m - m_new)
    i = jnp.exp(li - m_new)
    c_new = f * c + i * jnp.tanh(z)
    n_new = f * n + i
    h_new = jax.nn.sigmoid(o) * c_new / jnp.maximum(n_new, 1e-6)
    return h_new, {"c": c_new, "n": n_new, "h": h_new, "m": m_new}


def slstm_apply(p: dict, x, num_heads: int, ctx: ShardCtx, *,
                state: dict | None = None):
    """x: [B, S, d] -> (y, new_state).  Sequential over S (true recurrence)."""
    B, S, d = x.shape
    xproj = jnp.einsum("bsd,dge->bsge", x, p["wx"].value) + p["b"].value
    if state is None:
        state = init_slstm_state(B, d)
    if S == 1:
        h, new_state = _slstm_step(xproj[:, 0], p["r"].value, state, num_heads)
        hs = h[:, None]
    else:
        def body(st, xp):
            h, st2 = _slstm_step(xp, p["r"].value, st, num_heads)
            return st2, h
        new_state, hs = jax.lax.scan(body, state, xproj.transpose(1, 0, 2, 3))
        hs = hs.transpose(1, 0, 2)
    hs = hs.astype(x.dtype)
    # gated up/down projection FFN (proj factor 4/3)
    gate, up = jnp.split(jnp.einsum("bsd,df->bsf", hs, p["up"].value), 2, axis=-1)
    y = jnp.einsum("bsf,fd->bsd", jax.nn.gelu(gate) * up, p["down"].value)
    return ctx.constrain(y, ("batch", "seq", "embed")), new_state


def slstm_step_flops(d: int, num_heads: int) -> int:
    """Analytic per-step FLOPs of the recurrent part (for §Roofline)."""
    dh = d // num_heads
    return 2 * num_heads * dh * 4 * dh + 12 * d  # recurrent matvec + gates
