"""Unified model: one composable block stack covering all 10 assigned archs.

A config is compiled to *layer groups*: (unit_pattern, repeat) pairs where a
unit is a tuple of (mixer, ffn) block descriptors — mixer ∈ {attn, mamba,
mlstm, slstm}, ffn ∈ {ffn, moe, none}.  Each group scans over `repeat` with
stacked params (small HLO, fast multi-pod compile); `unroll=True` flattens
the scans for the roofline delta method (EXPERIMENTS.md §Roofline-method).

Examples:
  gemma-2b        [(attn+ffn,), 18]
  kimi-k2         [(attn+ffn,), 1] + [(attn+moe,), 60]        (first layer dense)
  jamba           [(mamba+ffn, mamba+moe, ... attn ..., ×8), 4]  (7:1, MoE every 2)
  xlstm-1.3b      [(mlstm ×7, slstm), 6]
  seamless        encoder [(attn+ffn,), 24] + decoder [(attn+xattn+ffn,), 24]

Modes: loss (train), prefill (fill caches, last-position logits), decode
(one token against caches/states).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.dist.context import ShardCtx
from repro.models import nn
from repro.models.attention import (attention_apply, init_attention,
                                    kv_repeat_for, positions_for)
from repro.models.ffn import ffn_apply, init_ffn
from repro.models.moe import init_moe, moe_apply
from repro.models.ssm import init_mamba, init_mamba_state, mamba_apply
from repro.models.xlstm import (init_mlstm, init_mlstm_state, init_slstm,
                                init_slstm_state, mlstm_apply, slstm_apply)
from repro.models.nn import KeyGen, Param

VOCAB_PAD_MULTIPLE = 2048  # pad vocab so 16-way 'model' sharding divides


def padded_vocab(cfg: ArchConfig) -> int:
    m = VOCAB_PAD_MULTIPLE
    return ((cfg.vocab_size + m - 1) // m) * m


# --------------------------------------------------------------------------
# layer groups
# --------------------------------------------------------------------------
def layer_groups(cfg: ArchConfig, *, encoder: bool = False) -> list[tuple[tuple, int]]:
    if encoder:
        return [((("attn", "ffn"),), cfg.encoder_layers)]
    if cfg.xlstm is not None:
        k = cfg.xlstm.slstm_every
        unit = tuple([("mlstm", "none")] * (k - 1) + [("slstm", "none")])
        assert cfg.num_layers % k == 0
        return [(unit, cfg.num_layers // k)]
    if cfg.attn_every:  # jamba: one attn per attn_every, MoE every other layer
        unit = []
        for i in range(cfg.attn_every):
            mixer = "attn" if i == cfg.attn_every // 2 else "mamba"
            ffn = "moe" if (cfg.moe is not None and i % 2 == 1) else "ffn"
            unit.append((mixer, ffn))
        assert cfg.num_layers % cfg.attn_every == 0
        return [(tuple(unit), cfg.num_layers // cfg.attn_every)]
    if cfg.moe is not None:
        groups: list[tuple[tuple, int]] = []
        fk = cfg.moe.first_k_dense
        if fk:
            groups.append(((("attn", "ffn"),), fk))
        groups.append(((("attn", "moe"),), cfg.num_layers - fk))
        return groups
    return [((("attn", "ffn"),), cfg.num_layers)]


# --------------------------------------------------------------------------
# blocks
# --------------------------------------------------------------------------
def _init_block(kg: KeyGen, desc, cfg: ArchConfig, dtype, *, cross: bool) -> dict:
    mixer, ffn = desc
    d = cfg.d_model
    p: dict[str, Any] = {"norm1": nn.init_norm(cfg.norm_type, d, jnp.float32)}
    if mixer == "attn":
        p["attn"] = init_attention(kg, cfg, dtype)
    elif mixer == "mamba":
        p["mamba"] = init_mamba(kg, d, cfg.mamba, dtype)
    elif mixer == "mlstm":
        p["mlstm"] = init_mlstm(kg, d, cfg.num_heads, cfg.xlstm, dtype)
    elif mixer == "slstm":
        p["slstm"] = init_slstm(kg, d, cfg.num_heads, cfg.xlstm, dtype)
    if cross:
        p["norm_x"] = nn.init_norm(cfg.norm_type, d, jnp.float32)
        p["xattn"] = init_attention(kg, cfg, dtype)
    if ffn == "ffn":
        p["norm2"] = nn.init_norm(cfg.norm_type, d, jnp.float32)
        p["ffn"] = init_ffn(kg, d, cfg.d_ff, cfg.mlp_type, dtype)
    elif ffn == "moe":
        p["norm2"] = nn.init_norm(cfg.norm_type, d, jnp.float32)
        p["moe"] = init_moe(kg, d, cfg.moe, cfg.mlp_type, dtype)
    return p


def _init_cache_block(desc, cfg: ArchConfig, batch: int, cache_len: int, ctx: ShardCtx,
                      dtype, *, cross: bool) -> dict:
    mixer, _ = desc
    c: dict[str, Any] = {}
    if mixer == "attn":
        K = cfg.num_kv_heads * kv_repeat_for(cfg, ctx)
        hd = cfg.resolved_head_dim
        slen = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
        c["attn"] = {
            "k": jnp.zeros((batch, slen, K, hd), dtype),
            "v": jnp.zeros((batch, slen, K, hd), dtype),
        }
        if cfg.sliding_window:
            c["attn"]["pos"] = jnp.full((slen,), -1, jnp.int32)
    elif mixer == "mamba":
        c["mamba"] = init_mamba_state(cfg, batch, dtype)
    elif mixer == "mlstm":
        di = int(cfg.d_model * cfg.xlstm.proj_factor_mlstm)
        c["mlstm"] = init_mlstm_state(batch, cfg.num_heads, di // cfg.num_heads)
    elif mixer == "slstm":
        c["slstm"] = init_slstm_state(batch, cfg.d_model)
    del cross
    return c


def _apply_block(desc, p, x, positions, cfg: ArchConfig, ctx: ShardCtx, *,
                 cache, cache_index, enc_out, causal, unroll, long_context,
                 ssm_dtype: str = "float32"):
    mixer, ffn = desc
    aux = jnp.zeros((), jnp.float32)
    new_cache: dict[str, Any] = {}
    h = nn.apply_norm(x, p["norm1"], cfg.norm_type)
    if mixer == "attn":
        a, nc = attention_apply(
            p["attn"], h, positions, cfg, ctx, causal=causal,
            cache=None if cache is None else cache["attn"],
            cache_index=cache_index, unroll=unroll,
            kv_seq_sharded=long_context and not cfg.sliding_window)
        if nc is not None and cache is not None:
            new_cache["attn"] = nc
        x = x + a
    elif mixer == "mamba":
        # unroll (roofline delta) uses one full-sequence chunk: identical math,
        # log-depth associative scan, far smaller HLO than 16 unrolled chunks
        a, st = mamba_apply(p["mamba"], h, cfg.mamba, ctx,
                            state=None if cache is None else cache["mamba"],
                            unroll=unroll,
                            chunk=x.shape[1] if unroll else 256,
                            scan_dtype=ssm_dtype)
        if cache is not None:
            new_cache["mamba"] = st
        x = x + a
    elif mixer == "mlstm":
        a, st = mlstm_apply(p["mlstm"], h, cfg.num_heads, cfg.xlstm, ctx,
                            state=None if cache is None else cache["mlstm"],
                            unroll=unroll)
        if cache is not None:
            new_cache["mlstm"] = st
        x = x + a
    elif mixer == "slstm":
        a, st = slstm_apply(p["slstm"], h, cfg.num_heads, ctx,
                            state=None if cache is None else cache["slstm"])
        if cache is not None:
            new_cache["slstm"] = st
        x = x + a
    if enc_out is not None:
        h = nn.apply_norm(x, p["norm_x"], cfg.norm_type)
        a, _ = attention_apply(p["xattn"], h, positions, cfg, ctx, causal=False,
                               cross_kv=enc_out)
        x = x + a
    if ffn in ("ffn", "moe"):
        h = nn.apply_norm(x, p["norm2"], cfg.norm_type)
        if ffn == "ffn":
            x = x + ffn_apply(p["ffn"], h, cfg.mlp_type, ctx)
        else:
            y, aux = moe_apply(p["moe"], h, cfg.moe, cfg.mlp_type, ctx)
            x = x + y
    return x, new_cache, aux


# --------------------------------------------------------------------------
# model
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Model:
    cfg: ArchConfig
    ctx: ShardCtx
    unroll: bool = False
    remat: bool = True
    long_context: bool = False
    # §Perf knobs (hillclimb levers; defaults = paper-faithful baseline)
    remat_policy: str = "nothing"   # nothing | dots  (what the bwd may keep)
    ssm_dtype: str = "float32"      # mamba scan tensor dtype (dA/dBx)

    @property
    def dtype(self):
        return jnp.bfloat16 if self.cfg.dtype == "bfloat16" else jnp.float32

    # ---- init -----------------------------------------------------------
    def init(self, key) -> dict:
        cfg, dtype = self.cfg, self.dtype
        kg = KeyGen(key)
        V = padded_vocab(cfg)
        params: dict[str, Any] = {
            "embed": nn.embed_init(kg(), V, cfg.d_model, dtype),
            "norm_f": nn.init_norm(cfg.norm_type, cfg.d_model, jnp.float32),
        }
        if not cfg.tie_embeddings:
            params["unembed"] = nn.dense_init(
                kg(), (cfg.d_model, V), ("embed", "vocab"), dtype)
        cross = cfg.is_encdec
        for gi, (unit, repeat) in enumerate(layer_groups(cfg)):
            def init_unit(k, unit=unit):
                ukg = KeyGen(k)
                return {f"b{i}": _init_block(ukg, desc, cfg, dtype, cross=cross)
                        for i, desc in enumerate(unit)}
            base = kg()
            keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(jnp.arange(repeat))
            params[f"group{gi}"] = nn.add_leading_axis(jax.vmap(init_unit)(keys))
        if cfg.is_encdec:
            for gi, (unit, repeat) in enumerate(layer_groups(cfg, encoder=True)):
                def init_unit_e(k, unit=unit):
                    ukg = KeyGen(k)
                    return {f"b{i}": _init_block(ukg, desc, cfg, dtype, cross=False)
                            for i, desc in enumerate(unit)}
                base = kg()
                keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(jnp.arange(repeat))
                params[f"enc_group{gi}"] = nn.add_leading_axis(jax.vmap(init_unit_e)(keys))
            params["enc_norm_f"] = nn.init_norm(cfg.norm_type, cfg.d_model, jnp.float32)
        return params

    def abstract_params(self, key=None) -> dict:
        key = key if key is not None else jax.random.PRNGKey(0)
        return jax.eval_shape(self.init, key)

    def param_count(self, params=None) -> int:
        params = params or self.abstract_params()
        vals, _ = nn.split_params(params)
        return sum(int(np.prod(v.shape)) for v in jax.tree_util.tree_leaves(vals))

    # ---- stacks ----------------------------------------------------------
    def _run_groups(self, params, x, positions, *, prefix="group", caches=None,
                    cache_index=None, enc_out=None, causal=True):
        cfg, ctx = self.cfg, self.ctx
        aux_total = jnp.zeros((), jnp.float32)
        new_caches: dict[str, Any] = {}
        groups = layer_groups(cfg, encoder=(prefix == "enc_group"))
        for gi, (unit, repeat) in enumerate(groups):
            gp = params[f"{prefix}{gi}"]
            gc = None if caches is None else caches[f"{prefix}{gi}"]

            def unit_body(carry, xs):
                xx, aux = carry
                up, uc = xs
                unew = {}
                for i, desc in enumerate(unit):
                    xx, nc, a = _apply_block(
                        desc, up[f"b{i}"], xx, positions, cfg, ctx,
                        cache=None if uc is None else uc[f"b{i}"],
                        cache_index=cache_index, enc_out=enc_out, causal=causal,
                        unroll=self.unroll, long_context=self.long_context,
                        ssm_dtype=self.ssm_dtype)
                    unew[f"b{i}"] = nc
                    aux = aux + a
                return (xx, aux), unew

            body = unit_body
            if self.remat:
                policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                          if self.remat_policy == "dots"
                          else jax.checkpoint_policies.nothing_saveable)
                body = jax.checkpoint(unit_body, policy=policy)
            (x, aux_total), nc_stack = jax.lax.scan(
                body, (x, aux_total), (gp, gc),
                unroll=repeat if self.unroll else 1)
            new_caches[f"{prefix}{gi}"] = nc_stack
        return x, aux_total, new_caches

    def _embed_inputs(self, params, batch):
        """tokens (+ modality stubs) -> (x [B,S,d], positions)."""
        cfg, ctx = self.cfg, self.ctx
        emb = params["embed"].value
        tokens = batch["tokens"]
        x = jnp.take(emb, tokens, axis=0)
        if cfg.name.startswith("gemma"):
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        if cfg.modality_stub == "image_patches" and "patches" in batch:
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        B, S = x.shape[0], x.shape[1]
        if cfg.rope_type == "mrope" and "positions" in batch:
            positions = batch["positions"]
        else:
            positions = positions_for(cfg, B, S)
        x = ctx.constrain(x, ("batch", "seq", "embed"))
        return x, positions

    def _logits(self, params, x):
        cfg = self.cfg
        x = nn.apply_norm(x, params["norm_f"], cfg.norm_type)
        if cfg.tie_embeddings:
            w = params["embed"].value
            logits = jnp.einsum("bsd,vd->bsv", x, w)
        else:
            logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].value)
        return self.ctx.constrain(logits.astype(jnp.float32), ("batch", "seq", "vocab"))

    def _encode(self, params, batch):
        cfg, ctx = self.cfg, self.ctx
        frames = batch["frames"].astype(self.dtype)  # stub: precomputed embeddings
        x = ctx.constrain(frames, ("batch", "seq", "embed"))
        positions = positions_for(cfg, x.shape[0], x.shape[1])
        x, _, _ = self._run_groups(params, x, positions, prefix="enc_group",
                                   causal=False)
        return nn.apply_norm(x, params["enc_norm_f"], cfg.norm_type)

    # ---- training --------------------------------------------------------
    def loss_fn(self, params, batch):
        cfg = self.cfg
        enc_out = self._encode(params, batch) if cfg.is_encdec else None
        x, positions = self._embed_inputs(params, batch)
        x, aux, _ = self._run_groups(params, x, positions, enc_out=enc_out)
        logits = self._logits(params, x)
        targets = batch["targets"]
        if logits.shape[1] != targets.shape[1]:  # vlm: patches prepended
            logits = logits[:, -targets.shape[1]:]
        logp = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
        mask = (targets >= 0).astype(jnp.float32)
        loss = -(ll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
        if cfg.moe is not None:
            loss = loss + 0.01 * aux
        return loss, {"ce": loss, "aux": aux}

    # ---- serving ---------------------------------------------------------
    def init_cache(self, batch_size: int, cache_len: int) -> dict:
        cfg, ctx = self.cfg, self.ctx
        caches: dict[str, Any] = {}
        for gi, (unit, repeat) in enumerate(layer_groups(cfg)):
            def one(_):
                return {f"b{i}": _init_cache_block(desc, cfg, batch_size, cache_len,
                                                   ctx, self.dtype, cross=cfg.is_encdec)
                        for i, desc in enumerate(unit)}
            caches[f"group{gi}"] = jax.vmap(one)(jnp.arange(repeat))
        return caches

    def prefill(self, params, batch, cache_len: int):
        """Returns (last-position logits, caches, enc_out|None)."""
        cfg = self.cfg
        enc_out = self._encode(params, batch) if cfg.is_encdec else None
        x, positions = self._embed_inputs(params, batch)
        caches = self.init_cache(x.shape[0], cache_len)
        x, _, new_caches = self._run_groups(params, x, positions, caches=caches,
                                            enc_out=enc_out)
        logits = self._logits(params, x[:, -1:])
        return logits, new_caches, enc_out

    def decode_step(self, params, caches, tokens, pos, enc_out=None):
        """tokens: [B, 1]; pos: scalar int32 (uniform across batch)."""
        cfg, ctx = self.cfg, self.ctx
        emb = params["embed"].value
        x = jnp.take(emb, tokens, axis=0)
        if cfg.name.startswith("gemma"):
            x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
        x = ctx.constrain(x, ("batch", "seq", "embed"))
        positions = positions_for(cfg, x.shape[0], 1, offset=pos)
        x, _, new_caches = self._run_groups(params, x, positions, caches=caches,
                                            cache_index=pos, enc_out=enc_out)
        logits = self._logits(params, x)
        return logits, new_caches


def build_model(cfg: ArchConfig, ctx: ShardCtx | None = None, **kw) -> Model:
    return Model(cfg, ctx if ctx is not None else ShardCtx(None, {}, {}), **kw)
