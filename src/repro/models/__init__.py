# NOTE: intentionally no eager re-exports — repro.dist.context imports
# repro.models.nn, so importing model here would create an import cycle.
