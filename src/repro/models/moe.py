"""Mixture-of-Experts with expert parallelism (EP) over the 'experts' axis.

This is the LM-side embodiment of GraphMP's selective scheduling (DESIGN.md
§5): the router's top-k assignment marks which "shards" (experts) can produce
updates for a token; only those are touched.  Dispatch is capacity-bounded
(tokens above capacity are dropped, MaxText-style) and sort-based — no
[T, E, C] one-hot tensor, which would be astronomically large for kimi's 384
experts.

Two execution paths with identical math:
  * local  — experts resident on every device (smoke tests / no mesh):
             batched GEMM over [E, C, d].
  * EP     — experts sharded over the 'experts' rule (mesh 'model' axis):
             shard_map with all_to_all to move token slots to their expert's
             device and back.  The all_to_all pair is the collective the
             roofline attributes to the paper's technique.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import MoEConfig
from repro.dist.context import ShardCtx
from repro.models import nn
from repro.models.nn import KeyGen, Param


def init_moe(kg: KeyGen, d: int, moe: MoEConfig, mlp_type: str, dtype) -> dict:
    E, f = moe.num_experts, moe.d_ff_expert
    p = {
        "router": nn.dense_init(kg(), (d, E), ("embed", "experts"), jnp.float32),
        "w_up": nn.dense_init(kg(), (E, d, f), ("experts", "embed", "expert_ff"), dtype),
        "w_down": nn.dense_init(kg(), (E, f, d), ("experts", "expert_ff", "embed"), dtype),
    }
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = nn.dense_init(kg(), (E, d, f), ("experts", "embed", "expert_ff"), dtype)
    if moe.num_shared_experts:
        from repro.models.ffn import init_ffn
        p["shared"] = init_ffn(kg, d, f * moe.num_shared_experts, mlp_type, dtype)
    return p


def _expert_ffn(p: dict, xe, mlp_type: str):
    """xe: [E, C, d] -> [E, C, d] (batched per-expert GEMMs)."""
    if mlp_type in ("swiglu", "geglu"):
        gate = jnp.einsum("ecd,edf->ecf", xe, p["w_gate"].value)
        up = jnp.einsum("ecd,edf->ecf", xe, p["w_up"].value)
        gate = jax.nn.silu(gate) if mlp_type == "swiglu" else jax.nn.gelu(gate)
        h = gate * up
    else:
        h = jax.nn.gelu(jnp.einsum("ecd,edf->ecf", xe, p["w_up"].value))
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].value)


def _route(router, xf, moe: MoEConfig, capacity: int):
    """Sort-based capacity dispatch.

    xf: [T, d] -> (dispatch_idx [E, C] int32 (token idx or -1),
                   combine_w   [E, C] float32)
    """
    T = xf.shape[0]
    E, k = moe.num_experts, moe.top_k
    logits = jnp.einsum("td,de->te", xf.astype(jnp.float32), router)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, k)           # [T, k]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    flat_e = top_e.reshape(-1)                        # [T*k]
    flat_w = top_w.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(T), k)
    order = jnp.argsort(flat_e)                       # group by expert
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    # position of each slot within its expert group
    start = jnp.searchsorted(se, jnp.arange(E))       # [E]
    pos = jnp.arange(T * k) - start[se]
    keep = pos < capacity
    slot = jnp.where(keep, se * capacity + pos, E * capacity)  # overflow bin
    dispatch_idx = jnp.full((E * capacity + 1,), -1, jnp.int32).at[slot].set(
        jnp.where(keep, st, -1).astype(jnp.int32))[: E * capacity].reshape(E, capacity)
    combine_w = jnp.zeros((E * capacity + 1,), jnp.float32).at[slot].set(
        jnp.where(keep, sw, 0.0))[: E * capacity].reshape(E, capacity)
    # load-balancing auxiliary loss (Switch-style)
    me = probs.mean(axis=0)
    ce = jnp.bincount(flat_e, length=E) / (T * k)
    aux = E * jnp.sum(me * ce)
    return dispatch_idx, combine_w, aux


def moe_apply(p: dict, x, moe: MoEConfig, mlp_type: str, ctx: ShardCtx):
    """x: [B, S, d] -> ([B, S, d], aux_loss)."""
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    T = B * S
    ep = ctx.axis_size("experts")
    # EP needs the expert count to divide the mesh axis (kimi 384, jamba 16);
    # otherwise fall back to TP-MoE: experts replicated, expert matrices
    # sharded on d_ff (mixtral's 8 experts on a 16-way axis).
    use_ep = ep > 1 and moe.num_experts % ep == 0
    # 'replicated' EP requires tokens to be replicated over the EP axis —
    # true when experts shard over 'model', false for the serve 2-D layout
    # where experts shard over 'data' (the token axis).
    ep_axis = ctx.rules.get("experts")
    dp = ctx.rules.get("batch") or ()
    dp_flat = (dp,) if isinstance(dp, str) else tuple(dp)
    replicated_ok = ep_axis not in dp_flat

    if use_ep and ctx.ep_mode == "replicated" and replicated_ok:
        y, aux = _moe_ep_replicated(p, xf, moe, mlp_type, ctx)
    elif use_ep:
        y, aux = _moe_ep(p, xf, moe, mlp_type, ctx)
    else:
        cap = max(-(-int(moe.capacity_factor * T * moe.top_k) // moe.num_experts), 1)
        dispatch_idx, combine_w, aux = _route(p["router"].value, xf, moe, cap)
        safe = jnp.maximum(dispatch_idx, 0)
        xe = xf[safe] * (dispatch_idx >= 0)[..., None].astype(x.dtype)  # [E, C, d]
        ye = _expert_ffn(p, xe, mlp_type)
        y = _combine(ye, dispatch_idx, combine_w, T, x.dtype)

    if "shared" in p:
        from repro.models.ffn import ffn_apply
        y = y + ffn_apply(p["shared"], x, mlp_type, ctx).reshape(T, d)
    return y.reshape(B, S, d), aux


def _combine(ye, dispatch_idx, combine_w, T, dtype):
    """Scatter-add expert outputs back to token order with routing weights."""
    w = combine_w[..., None].astype(ye.dtype)
    flat_idx = jnp.where(dispatch_idx >= 0, dispatch_idx, T).reshape(-1)
    contrib = (ye * w).reshape(-1, ye.shape[-1])
    y = jnp.zeros((T + 1, ye.shape[-1]), ye.dtype).at[flat_idx].add(contrib)
    return y[:T].astype(dtype)


def _moe_ep(p, xf, moe: MoEConfig, mlp_type, ctx: ShardCtx):
    """Expert-parallel path (DP×EP grid, DeepSpeed-MoE style).

    Tokens stay sharded over the data axes; each device routes its *local*
    tokens (so dispatch buffers scale with T_local, not global T — essential
    for kimi's 384 experts), then a pair of all_to_alls over the 'experts'
    mesh axis moves capacity slots to expert owners and back.
    """
    mesh = ctx.mesh
    axis = ctx.rules.get("experts")
    dp = ctx.rules.get("batch")
    # optional second-level TP on the expert ff dim (serve 2-D layout, §Perf)
    ff_axis = ctx.weight_rules.get("expert_ff")
    ff_axis = ff_axis if isinstance(ff_axis, str) and ff_axis != axis else None
    E = moe.num_experts
    T, d = xf.shape
    dp_size = ctx.axis_size("batch")
    T_local = T // max(dp_size, 1)
    cap = max(-(-int(moe.capacity_factor * T_local * moe.top_k) // E), 1)
    wg = p.get("w_gate")

    def local(xf_b, router, wg_b, wu, wd):
        di, cw, aux = _route(router, xf_b, moe, cap)
        safe = jnp.maximum(di, 0)
        xe = xf_b[safe] * (di >= 0)[..., None].astype(xf_b.dtype)  # [E, C, d]
        xe = jax.lax.all_to_all(xe, axis, split_axis=0, concat_axis=1, tiled=True)
        sub = {"w_up": Param(wu, None), "w_down": Param(wd, None)}
        if wg is not None:
            sub["w_gate"] = Param(wg_b, None)
        ye = _expert_ffn(sub, xe, mlp_type)
        if ff_axis is not None:  # down-proj contracted a sharded ff dim
            ye = jax.lax.psum(ye, ff_axis)
        ye = jax.lax.all_to_all(ye, axis, split_axis=1, concat_axis=0, tiled=True)
        y = _combine(ye, di, cw, xf_b.shape[0], xf_b.dtype)
        if dp is not None:
            aux = jax.lax.pmean(aux, dp)
        return y, aux

    w_up_spec = P(axis, None, ff_axis)
    w_dn_spec = P(axis, ff_axis, None)
    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(dp), P(), w_up_spec if wg is not None else P(),
                  w_up_spec, w_dn_spec),
        out_specs=(P(dp), P()),
        check_vma=False,
    )
    y, aux = fn(xf, p["router"].value,
                wg.value if wg is not None else jnp.zeros((), xf.dtype),
                p["w_up"].value, p["w_down"].value)
    return y, aux


def _moe_ep_replicated(p, xf, moe: MoEConfig, mlp_type, ctx: ShardCtx):
    """No-token-movement EP (§Perf iteration): activations are already
    replicated over the 'experts' mesh axis (tokens shard over batch/data
    only), so moving them with all_to_all is pure waste.  Each device routes
    the local tokens, gathers capacity slots for its OWN E/ep experts
    directly from its resident copy of x, runs the expert GEMMs, scatters
    into a local partial y, and a single psum over the EP axis combines.

    Wire bytes per layer: 2 × T_local·d (psum) instead of
    2 × E·C·d ≈ 2 × T_local·d·top_k·capacity_factor (a2a) — a ~2·k·cf×
    reduction (20× for kimi's top-8 @ cf 1.25).
    """
    mesh = ctx.mesh
    axis = ctx.rules.get("experts")
    dp = ctx.rules.get("batch")
    E = moe.num_experts
    ep = ctx.axis_size("experts")
    E_local = E // ep
    T, d = xf.shape
    dp_size = ctx.axis_size("batch")
    T_local = T // max(dp_size, 1)
    cap = max(-(-int(moe.capacity_factor * T_local * moe.top_k) // E), 1)
    wg = p.get("w_gate")

    def local(xf_b, router, wg_b, wu, wd):
        di, cw, aux = _route(router, xf_b, moe, cap)  # full dispatch, local
        me = jax.lax.axis_index(axis)
        sl = me * E_local
        di_loc = jax.lax.dynamic_slice(di, (sl, 0), (E_local, cap))
        cw_loc = jax.lax.dynamic_slice(cw, (sl, 0), (E_local, cap))
        safe = jnp.maximum(di_loc, 0)
        xe = xf_b[safe] * (di_loc >= 0)[..., None].astype(xf_b.dtype)
        sub = {"w_up": Param(wu, None), "w_down": Param(wd, None)}
        if wg is not None:
            sub["w_gate"] = Param(wg_b, None)
        ye = _expert_ffn(sub, xe, mlp_type)
        y_part = _combine(ye, di_loc, cw_loc, xf_b.shape[0], jnp.float32)
        y = jax.lax.psum(y_part, axis).astype(xf_b.dtype)
        if dp is not None:
            aux = jax.lax.pmean(aux, dp)
        return y, aux

    specs_w = P(axis)
    fn = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(dp), P(), specs_w if wg is not None else P(), specs_w, specs_w),
        out_specs=(P(dp), P()),
        check_vma=False,
    )
    y, aux = fn(xf, p["router"].value,
                wg.value if wg is not None else jnp.zeros((), xf.dtype),
                p["w_up"].value, p["w_down"].value)
    return y, aux
